"""Fault-tolerance bench: snapshot overhead, recovery cost, serving loss.

Three workloads, one per DESIGN.md §17 claim:

  * ``snapshot_overhead`` — step latency of a bound session with
    checkpointing ``off``, synchronous (``sync``: device_get + npz write
    + fsync on the step turn), and asynchronous (``async``: device_get
    only; a background writer publishes).  The gated metric is
    ``save_offturn_speedup``: the sync/async ratio of one save call's
    ON-TURN latency, clipped at 4x for baseline stability — well above
    1 while the write stays off the step turn, collapsing to ~1 if the
    async path ever degrades to blocking.
    Step-level ratios are reported but not gated: on a CPU-only
    container the background writer contends with the compute for
    cores, which a real accelerator host does not.
  * ``recovery`` — a scripted hard host kill against snapshot cadences
    ``every ∈ {1, 2, 4}``: rollback depth (steps of lost work), MTTR in
    steps, the wasted-work fraction, and ``goodput`` (useful steps /
    executed steps — the gated metric; tighter cadence → higher goodput).
    All four are exact step-count identities, so the rows are
    deterministic and machine-portable.
  * ``serving_host_loss`` — a mid-flight host loss preempts every
    resident sequence and drops the prefix index; requeued requests
    regenerate on the survivors.  ``token_exact`` (gated) is 1.0 iff
    every completion is token-identical to an uninterrupted reference
    run — greedy decode makes recovery lossless, not just graceful.
"""

from __future__ import annotations

import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, List

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core.placement import ClusterSpec  # noqa: E402

TASKS = ("img_text", "audio_text", "audio_vision")


def _bound_session(cluster, *, mgr=None, sources=()):
    from repro.runtime import tiny_multitask_clip
    from repro.session import (
        CheckpointCallbacks,
        SessionConfig,
        SpindleSession,
    )

    return SpindleSession(
        SessionConfig(cluster=cluster),
        model_factory=lambda ts: tiny_multitask_clip(n_tasks=len(ts)),
        tasks=TASKS,
        callbacks=[CheckpointCallbacks(mgr)] if mgr is not None else [],
        event_sources=list(sources),
    ).bind()


def _snapshot_overhead_rows(steps: int, warmup: int) -> List[Dict]:
    from repro.ckpt import AsyncCheckpointManager, CheckpointManager

    cluster = ClusterSpec(n_devices=8, island_size=4, mem_bytes=96e9)

    def measure(mode: str) -> Dict:
        mgr = None
        if mode == "sync":
            mgr = CheckpointManager(
                tempfile.mkdtemp(prefix="bench_sync_"), every=1, keep=2
            )
        elif mode == "async":
            mgr = AsyncCheckpointManager(
                tempfile.mkdtemp(prefix="bench_async_"), every=1, keep=2
            )
        sess = _bound_session(cluster, mgr=mgr)
        for _ in range(warmup):
            sess.step()
        times = []
        for _ in range(steps):
            t0 = time.perf_counter()
            sess.step()
            times.append(time.perf_counter() - t0)
        drain = 0.0
        save_calls = []
        if mgr is not None:
            t0 = time.perf_counter()
            mgr.wait()
            drain = time.perf_counter() - t0
            # the on-turn cost of ONE save call, on a DRAINED manager:
            # sync pays device_get + npz write + fsync inline; async pays
            # device_get only (the write happens on the background
            # thread).  min-of-5 is the intrinsic cost — this is the
            # gated signal; step-level ratios on a CPU container also
            # absorb writer-thread contention with the compute, which a
            # real accelerator host does not have.
            tree = {"params": sess.params, "opt": sess.opt_state}
            for i in range(5):
                t0 = time.perf_counter()
                mgr.save(10_000 + i, tree)
                save_calls.append(time.perf_counter() - t0)
                mgr.wait()
        row = {
            "bench": "faults",
            "workload": "snapshot_overhead",
            "policy": mode,
            "devices": cluster.n_devices,
            "steps": steps,
            "mean_step_ms": float(np.mean(times)) * 1e3,
            "p99_step_ms": float(np.percentile(times, 99)) * 1e3,
            "save_call_ms": (
                float(np.min(save_calls)) * 1e3 if save_calls else 0.0
            ),
            "drain_ms": drain * 1e3,
        }
        if mode == "async":
            row["saves_written"] = mgr.saves_written
            row["saves_dropped"] = mgr.saves_dropped
        return row

    rows = [measure(m) for m in ("off", "sync", "async")]
    off, sync, asyn = rows
    # gated: how much of the save left the step turn.  Clipped at 4x —
    # the raw ratio's tail is millisecond-noise (observed 6–13x on this
    # container) while the failure mode it guards is async degrading to
    # BLOCKING writes, which collapses the ratio to ~1 and trips the
    # gate from any clipped baseline.
    asyn["save_offturn_speedup"] = min(
        4.0, sync["save_call_ms"] / max(asyn["save_call_ms"], 1e-9)
    )
    # informative (NOT gated: absorbs CPU writer/compute contention)
    asyn["step_ratio_vs_sync"] = (
        sync["mean_step_ms"] / max(asyn["mean_step_ms"], 1e-9)
    )
    asyn["step_ratio_vs_off"] = (
        off["mean_step_ms"] / max(asyn["mean_step_ms"], 1e-9)
    )
    return rows


def _recovery_rows(steps: int, kill_at: int) -> List[Dict]:
    from repro.ckpt import AsyncCheckpointManager
    from repro.launch.faults import FaultInjector, FaultScript

    cluster = ClusterSpec(
        n_devices=8, island_size=4, devices_per_host=2, mem_bytes=96e9
    )
    rows: List[Dict] = []
    for every in (1, 2, 4):
        mgr = AsyncCheckpointManager(
            tempfile.mkdtemp(prefix="bench_rec_"), every=every, keep=4
        )
        inj = FaultInjector(
            cluster.n_hosts,
            schedule=[FaultScript(step=kill_at, hosts=(1,))],
        )
        sess = _bound_session(cluster, mgr=mgr, sources=[inj])
        step_walls = []
        for _ in range(steps):
            t0 = time.perf_counter()
            sess.step()
            step_walls.append(time.perf_counter() - t0)
        mgr.wait()
        restores = [r for r in sess.replans if r.mode == "restore"]
        if len(restores) != 1:
            raise SystemExit(
                f"[bench_faults] every={every}: expected exactly one "
                f"restore replan, got {len(restores)}"
            )
        rb = restores[0].rollback_steps
        executed = steps + rb
        # the kill step's wall time is the MTTR in seconds: the step that
        # absorbed rollback + re-mesh + replay, vs a healthy median (NOT
        # max(): the first step carries JIT compilation, not recovery)
        healthy = float(np.median(step_walls))
        rows.append(
            {
                "bench": "faults",
                "workload": "recovery",
                "policy": f"every{every}",
                "devices": cluster.n_devices,
                "steps": steps,
                "kill_at": kill_at,
                "snapshot_every": every,
                "restored_step": restores[0].restored_step,
                "rollback_depth": rb,
                "mttr_steps": rb,
                "mttr_s": max(0.0, float(step_walls[kill_at]) - healthy),
                "wasted_work_frac": rb / executed,
                "goodput": steps / executed,
            }
        )
    return rows


def _serving_host_loss_row(requests: int, kill_after: int) -> Dict:
    from repro.serving.queue import Request
    from repro.serving.session import ServingConfig, ServingSession

    rng = np.random.default_rng(7)
    prompts = [
        np.asarray(rng.integers(1, 200, size=8), np.int32)
        for _ in range(requests)
    ]

    def mk_cfg():
        return ServingConfig(
            arch="qwen3-0.6b",
            max_slots=2,
            cache_len=64,
            kv_layout="paged",
            prefix_sharing=True,
            prefill_chunk=8,
            replan="off",
        )

    def mk_requests():
        return [
            Request(rid=i, tokens=prompts[i], max_new_tokens=6,
                    family="bench", arrival=0.0)
            for i in range(requests)
        ]

    ref = ServingSession(mk_cfg())
    for r in mk_requests():
        ref.submit(r)
    while ref.busy:
        ref.step()

    sess = ServingSession(mk_cfg(), model=ref.model, params=ref.params)
    for r in mk_requests():
        sess.submit(r)
    t0 = time.perf_counter()
    for _ in range(kill_after):
        sess.step()
    requeued = sess.host_failed()
    while sess.busy:
        sess.step()
    wall = time.perf_counter() - t0

    exact = all(
        sess.results[i].tokens == ref.results[i].tokens
        for i in range(requests)
    )
    kv = sess.batcher.kv_stats()
    return {
        "bench": "faults",
        "workload": "serving_host_loss",
        "policy": "host_loss",
        "requests": requests,
        "slots": 2,
        "kill_after_steps": kill_after,
        "host_loss_requeued": requeued,
        "host_loss_preemptions": kv["kv_host_loss_preemptions"],
        "completed": len(sess.results),
        "token_exact": 1.0 if exact else 0.0,
        "wall_seconds": wall,
    }


def run(smoke: bool = False) -> List[Dict]:
    if smoke:
        rows = _snapshot_overhead_rows(steps=6, warmup=2)
        rows += _recovery_rows(steps=6, kill_at=3)
        rows.append(_serving_host_loss_row(requests=4, kill_after=2))
    else:
        rows = _snapshot_overhead_rows(steps=12, warmup=3)
        rows += _recovery_rows(steps=10, kill_at=7)
        rows.append(_serving_host_loss_row(requests=6, kill_after=3))
    return rows


def main(rows: List[Dict]) -> None:
    snap = [r for r in rows if r["workload"] == "snapshot_overhead"]
    print(f"{'ckpt':<7} {'mean_step_ms':>13} {'p99_step_ms':>12} "
          f"{'save_call_ms':>13} {'drain_ms':>9}")
    for r in snap:
        print(f"{r['policy']:<7} {r['mean_step_ms']:>13.2f} "
              f"{r['p99_step_ms']:>12.2f} {r['save_call_ms']:>13.2f} "
              f"{r['drain_ms']:>9.2f}")
    a = snap[-1]
    print(f"async save: {a['save_offturn_speedup']:.1f}x less on-turn "
          f"latency than sync (clipped at 4x; step ratio "
          f"{a['step_ratio_vs_sync']:.2f}x vs sync, "
          f"{a['step_ratio_vs_off']:.2f}x vs off)\n")
    print(f"{'cadence':<8} {'rollback':>9} {'wasted':>8} {'goodput':>8} "
          f"{'mttr_s':>8}")
    for r in rows:
        if r["workload"] != "recovery":
            continue
        print(f"{r['policy']:<8} {r['rollback_depth']:>9d} "
              f"{r['wasted_work_frac']:>8.1%} {r['goodput']:>8.3f} "
              f"{r['mttr_s']:>8.3f}")
    s = [r for r in rows if r["workload"] == "serving_host_loss"][0]
    print(f"\nserving host loss: {s['host_loss_requeued']} requeued of "
          f"{s['requests']}, {s['completed']} completed, "
          f"token_exact={s['token_exact']:.0f}")


if __name__ == "__main__":
    main(run())
