"""Bubble co-location bench: decode inside training idle windows.

Runs the smoke fleet mix (two duplicate CLIP training jobs + one serving
job) at EQUAL total work under two policies:

  * ``colocate``   — the serving job holds NO lease: it rides a training
                     job's plan timeline as a co-resident tenant, its
                     decode steps slotted into idle windows whose memory
                     headroom fits the tenant's KV page budget,
  * ``time-sliced`` — the fifo baseline: every job (serving included)
                      gets the whole cluster in round-robin slices, so
                      serving time comes straight out of training time.

Time is the scheduler's deterministic virtual clock.  The combined
goodput — (training steps + generated tokens) / fleet makespan — is the
headline: co-location should deliver the same work in less wall-clock
because decode runs inside bubbles the trainer could not fill anyway.
The colocate row carries the relative metric the regression gate tracks
(``goodput_speedup_vs_timesliced``, higher-is-better) plus the
correctness flag ``token_exact``: the co-located tenant's generated
tokens must be IDENTICAL to a solo :class:`repro.serving.ServingSession`
run over the same scripted trace — window scheduling may move decode in
time, never change what it decodes.
"""

from __future__ import annotations

import sys
from pathlib import Path
from typing import Dict, List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.fleet import run_fleet  # noqa: E402

STEPS = 8
REQUESTS = 3


def _tenant_tokens(metrics: Dict) -> Dict[int, Tuple[int, ...]]:
    """rid -> generated tokens of every serve job in the fleet run."""
    out: Dict[int, Tuple[int, ...]] = {}
    for h in metrics["_handles"].values():
        if h.spec.kind != "serve" or h.session is None:
            continue
        for rid, res in h.session.results.items():
            out[rid] = tuple(res.tokens)
    return out


def _solo_tokens(requests: int) -> Dict[int, Tuple[int, ...]]:
    """The reference decode: ONE ServingSession over the same trace."""
    from repro.fleet.scheduler import FleetScheduler
    from repro.serving import ServingConfig, ServingSession

    spec = next(
        s for s in _smoke_specs(requests) if s.kind == "serve"
    )
    sess = ServingSession(
        ServingConfig(
            arch=spec.arch,
            max_slots=spec.slots,
            cache_len=spec.cache_len,
            replan="off",  # pure decode reference; no planner in the loop
        )
    )
    pending = FleetScheduler(jobs=())._make_requests(spec)
    while pending or sess.busy:
        while pending and pending[0].arrival <= sess.steps:
            sess.submit(pending.pop(0))
        sess.step()
    return {rid: tuple(r.tokens) for rid, r in sess.results.items()}


def _smoke_specs(requests: int):
    from repro.launch.fleet import smoke_jobs

    return smoke_jobs(STEPS, requests)


def _work(metrics: Dict) -> Tuple[int, int]:
    """(training steps, generated tokens) completed by the fleet run."""
    train_steps = sum(
        r["steps_done"] for r in metrics["jobs"] if r["kind"] == "train"
    )
    tokens = sum(len(t) for t in _tenant_tokens(metrics).values())
    return train_steps, tokens


def run(smoke: bool = False) -> List[Dict]:
    # the virtual clock makes the grid cheap either way; smoke trims the
    # serving trace only (fewer training steps would shrink the window
    # supply the co-location contract is exercised against)
    requests = 2 if smoke else REQUESTS
    rows: List[Dict] = []
    metrics: Dict[str, Dict] = {}
    for policy in ("colocate", "fifo"):
        m = run_fleet(
            policy,
            smoke=True,  # 2 duplicate train jobs + 1 serving job
            steps=STEPS,
            requests=requests,
            straggler_at=-1,  # clean comparison; CI smoke covers eviction
            verbose=False,
        )
        metrics[policy] = m
        train_steps, tokens = _work(m)
        goodput = (train_steps + tokens) / max(m["makespan_s"], 1e-12)
        rows.append(
            {
                "bench": "colocation",
                "policy": policy,
                "devices": 32,
                "requests": requests,
                "steps": STEPS,
                "makespan_s": m["makespan_s"],
                "train_steps": train_steps,
                "output_tokens": tokens,
                "combined_goodput_per_s": goodput,
                "colocated_steps": m["colocated_steps"],
                "windows_seen": m["windows_seen"],
                "deferred_windows": m["deferred_windows"],
                "colocations": m["lease"]["colocations"],
                "device_idle_frac": m["device_idle_frac"],
                "job_rows": m["jobs"],
            }
        )
    co, ts = rows[0], rows[1]
    # equal work is the precondition of the goodput comparison
    assert (co["train_steps"], co["output_tokens"]) == (
        ts["train_steps"], ts["output_tokens"]
    ), "colocate and time-sliced runs completed different work"
    co["goodput_speedup_vs_timesliced"] = (
        co["combined_goodput_per_s"] / max(ts["combined_goodput_per_s"], 1e-12)
    )
    co["token_exact"] = (
        _tenant_tokens(metrics["colocate"]) == _solo_tokens(requests)
    )
    return rows


def main(rows: List[Dict]) -> None:
    print(
        f"{'policy':<10} {'makespan_s':>11} {'goodput/s':>10} "
        f"{'coloc_steps':>12} {'windows':>8} {'deferred':>9}"
    )
    for r in rows:
        print(
            f"{r['policy']:<10} {r['makespan_s']:>11.3f} "
            f"{r['combined_goodput_per_s']:>10.1f} "
            f"{r['colocated_steps']:>12d} {r['windows_seen']:>8d} "
            f"{r['deferred_windows']:>9d}"
        )
    co = rows[0]
    print(
        f"colocate: {co['goodput_speedup_vs_timesliced']:.2f}x combined "
        f"goodput vs time-sliced at equal work "
        f"(token_exact={co['token_exact']})"
    )


if __name__ == "__main__":
    main(run())
