"""Fig. 4 — scalability-estimator accuracy: piecewise α–β vs held-out points.

Profile a sparse power-of-two grid, fit the scaling curves, then evaluate
prediction error at the held-out (non-profiled) allocations against the
full cost model.  The paper's single-piece α–β baseline is included to show
why the *piecewise* fit is needed for heterogeneous MetaOps.
"""

from __future__ import annotations

import math
from typing import Dict, List

from repro.core import (
    ParallelConfig,
    ScalingCurve,
    V5E,
    contract,
    make_time_fn,
    valid_allocations,
)
from repro.core.estimator import enumerate_configs
from repro.core.workloads import multitask_clip


def _best_time(m, n, time_fn) -> float:
    return min(
        (time_fn(m, c) for c in enumerate_configs(m, n)), default=math.inf
    )


def run() -> List[Dict]:
    g = multitask_clip(4)
    mg = contract(g)
    time_fn = make_time_fn(V5E)
    N = 16
    rows = []
    for mid, m in sorted(mg.meta_ops.items()):
        # profile every other valid allocation, hold out the rest (the
        # paper's "several discrete data points")
        valids = valid_allocations(m, N)
        grid = valids[::2] if len(valids) > 3 else valids
        ns, ts, cfgs = [], [], []
        for n in grid:
            t = _best_time(m, n, time_fn)
            if math.isfinite(t):
                ns.append(n)
                ts.append(t)
                cfgs.append(ParallelConfig(dp=n))
        if len(ns) < 2:
            continue
        curve = ScalingCurve(ns=ns, ts=ts, configs=cfgs)
        # single-piece α–β baseline through the endpoints
        n0, n1 = curve.ns[0], curve.ns[-1]
        t0, t1 = curve.ts[0], curve.ts[-1]
        if n0 != n1:
            beta = (t0 - t1) / (1 / n0 - 1 / n1)
            alpha = t0 - beta / n0
        else:
            alpha, beta = t0, 0.0
        held_out = [n for n in valids if n not in curve.ns]
        if not held_out:
            continue
        pw_err, ab_err = [], []
        for n in held_out:
            truth = _best_time(m, n, time_fn)
            if not math.isfinite(truth):
                continue
            pw_err.append(abs(curve.estimate(n) - truth) / truth)
            ab_err.append(abs(alpha + beta / n - truth) / truth)
        if pw_err:
            rows.append(
                {
                    "bench": "estimator",
                    "meta": m.name,
                    "piecewise_err_pct": 100 * sum(pw_err) / len(pw_err),
                    "single_ab_err_pct": 100 * sum(ab_err) / len(ab_err),
                    "speedup_at_N": curve.speedup(N),
                }
            )
    return rows


def main(rows=None) -> None:
    rows = run() if rows is None else rows
    print(f"{'MetaOp':28s} {'piecewise err':>14s} {'single α–β err':>15s} "
          f"{'ς(16)':>6s}")
    seen = set()
    for r in rows:
        if r["meta"] in seen:
            continue
        seen.add(r["meta"])
        print(f"{r['meta']:28s} {r['piecewise_err_pct']:13.2f}% "
              f"{r['single_ab_err_pct']:14.2f}% {r['speedup_at_N']:6.2f}")
    pw = sum(r["piecewise_err_pct"] for r in rows) / len(rows)
    ab = sum(r["single_ab_err_pct"] for r in rows) / len(rows)
    print(f"mean held-out error: piecewise {pw:.2f}% vs single α–β {ab:.2f}%")


if __name__ == "__main__":
    main()
