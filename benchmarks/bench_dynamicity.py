"""Appendix-D analogue — dynamicity: workload shift → re-plan payoff.

Tasks are added/completed over time (the paper §1: "the proportion of
different data modalities in MT workloads may shift over time").  We
compare three policies on a task-count trajectory:

  * ``replan``   — Spindle re-plans at every shift (the paper's hook),
  * ``stale``    — keep the plan built for the initial task set; removed
                   tasks leave holes, added tasks run sequentially after,
  * ``sequential`` — the workload-unaware baseline throughout.

Reported: total simulated time over the trajectory and the re-plan
overhead (planner wall time is < 0.2 s per shift, §Fig. 12).
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import ClusterSpec, simulate_sequential, simulate_spindle
from repro.core.workloads import multitask_clip

TRAJECTORY = [4, 6, 6, 3, 5, 2]  # active task count per phase
ITERS_PER_PHASE = 25


def run() -> List[Dict]:
    cluster = ClusterSpec(n_devices=16, island_size=8, mem_bytes=96e9)
    rows = []

    # replan policy: plan per phase
    t_replan, plan_overhead = 0.0, 0.0
    for k in TRAJECTORY:
        g = multitask_clip(k)
        t0 = time.perf_counter()
        res, _ = simulate_spindle(g, cluster)
        plan_overhead += time.perf_counter() - t0
        t_replan += res.makespan * ITERS_PER_PHASE

    # stale policy: the first phase's per-task time, applied to every phase
    # (removed tasks leave idle allocations; added tasks run sequentially)
    g0 = multitask_clip(TRAJECTORY[0])
    res0, _ = simulate_spindle(g0, cluster)
    per_iter0 = res0.makespan
    t_stale = 0.0
    for k in TRAJECTORY:
        extra = 0.0
        if k > TRAJECTORY[0]:  # new tasks appended sequentially
            g_extra = multitask_clip(k)
            seq = simulate_sequential(g_extra, cluster)
            extra = seq.makespan * (k - TRAJECTORY[0]) / k
        t_stale += (per_iter0 + extra) * ITERS_PER_PHASE

    # sequential baseline
    t_seq = 0.0
    for k in TRAJECTORY:
        res = simulate_sequential(multitask_clip(k), cluster)
        t_seq += res.makespan * ITERS_PER_PHASE

    rows.append({
        "bench": "dynamicity",
        "trajectory": TRAJECTORY,
        "replan_total_s": t_replan,
        "stale_total_s": t_stale,
        "sequential_total_s": t_seq,
        "replan_overhead_s": plan_overhead,
        "speedup_vs_stale": t_stale / t_replan,
        "speedup_vs_sequential": t_seq / t_replan,
    })
    return rows


def main() -> None:
    r = run()[0]
    print(f"task trajectory {r['trajectory']} × {ITERS_PER_PHASE} iters/phase")
    print(f"  re-plan each shift : {r['replan_total_s']:8.2f} s "
          f"(+{r['replan_overhead_s']*1e3:.0f} ms total planner time)")
    print(f"  stale initial plan : {r['stale_total_s']:8.2f} s "
          f"({r['speedup_vs_stale']:.2f}x slower)")
    print(f"  sequential baseline: {r['sequential_total_s']:8.2f} s "
          f"({r['speedup_vs_sequential']:.2f}x slower)")


if __name__ == "__main__":
    main()
