"""Appendix-D analogue — dynamicity: workload shift → re-plan payoff.

Tasks are added/completed over time (the paper §1: "the proportion of
different data modalities in MT workloads may shift over time").  We
compare four policies on a task-count trajectory:

  * ``replan``      — Spindle re-plans from scratch at every shift (the
                      paper's hook),
  * ``incremental`` — Spindle replans through a :class:`SpindleSession`:
                      each phase shift arrives as a burst of TaskArrived/
                      TaskCompleted events driven through
                      ``session.signal_all`` — the real production path —
                      which coalesces the burst into one replan; unchanged
                      phases generate no events and skip planning outright,
                      shifted workloads reuse cached plans (exact
                      signature hits), cached scaling curves, warm-started
                      MPSP brackets, and any unchanged MetaLevels
                      (repro.core.plancache),
  * ``stale``       — keep the plan built for the initial task set; removed
                      tasks leave holes, added tasks run sequentially after,
  * ``sequential``  — the workload-unaware baseline throughout.

Reported: total simulated time over the trajectory, per-policy planner wall
time per shift (< 0.2 s per shift, §Fig. 12), and the cache hit rate.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (
    ClusterSpec,
    plan,
    simulate_plan,
    simulate_sequential,
)
from repro.core.workloads import multitask_clip
from repro.launch.events import TaskArrived, TaskCompleted
from repro.session import SessionConfig, SpindleSession

TRAJECTORY = [4, 6, 6, 3, 5, 2]  # active task count per phase
SMOKE_TRAJECTORY = [3, 4, 2]  # CI smoke: same schema, smaller graphs
ITERS_PER_PHASE = 25


def run(smoke: bool = False) -> List[Dict]:
    trajectory = SMOKE_TRAJECTORY if smoke else TRAJECTORY
    cluster = ClusterSpec(n_devices=16, island_size=8, mem_bytes=96e9)
    rows = []

    # replan policy: full plan per phase (graph construction INSIDE the
    # timer, matching the session path which also rebuilds the graph —
    # both measure "cost to get a new plan when the workload shifts")
    t_replan, replan_overhead = 0.0, 0.0
    for k in trajectory:
        t0 = time.perf_counter()
        p = plan(multitask_clip(k), cluster)
        replan_overhead += time.perf_counter() - t0
        t_replan += simulate_plan(p, cluster).makespan * ITERS_PER_PHASE

    # incremental policy: a plan-only session whose shift sequence arrives
    # as TaskArrived/TaskCompleted event bursts — each phase shift goes
    # through session.signal_all, which coalesces the burst into ONE replan
    # through the session's PlanCache (exact hits + per-level reuse +
    # memoized curves + warm-started bisection); correctness falls back to
    # full replan
    session = SpindleSession(
        SessionConfig(cluster=cluster),
        graph_factory=lambda tasks: multitask_clip(len(tasks)),
        tasks=tuple(f"task{i}" for i in range(trajectory[0])),
    )
    t0 = time.perf_counter()
    p = session.plan()
    inc_overhead = time.perf_counter() - t0
    t_inc = simulate_plan(p, cluster).makespan * ITERS_PER_PHASE
    active = trajectory[0]
    for k in trajectory[1:]:
        events = []
        while active < k:
            events.append(TaskArrived(f"task{active}"))
            active += 1
        while active > k:
            active -= 1
            events.append(TaskCompleted(f"task{active}"))
        t0 = time.perf_counter()
        if events:
            p = session.signal_all(events)
        inc_overhead += time.perf_counter() - t0
        t_inc += simulate_plan(p, cluster).makespan * ITERS_PER_PHASE
    cache = session.cache
    inc_replans = len(session.replans) + 1  # + the initial plan

    # stale policy: the first phase's per-task time, applied to every phase
    # (removed tasks leave idle allocations; added tasks run sequentially)
    g0 = multitask_clip(trajectory[0])
    per_iter0 = simulate_plan(plan(g0, cluster), cluster).makespan
    t_stale = 0.0
    for k in trajectory:
        extra = 0.0
        if k > trajectory[0]:  # new tasks appended sequentially
            g_extra = multitask_clip(k)
            seq = simulate_sequential(g_extra, cluster)
            extra = seq.makespan * (k - trajectory[0]) / k
        t_stale += (per_iter0 + extra) * ITERS_PER_PHASE

    # sequential baseline
    t_seq = 0.0
    for k in trajectory:
        res = simulate_sequential(multitask_clip(k), cluster)
        t_seq += res.makespan * ITERS_PER_PHASE

    n = len(trajectory)
    rows.append({
        "bench": "dynamicity",
        "trajectory": trajectory,
        "replan_total_s": t_replan,
        "incremental_total_s": t_inc,
        "stale_total_s": t_stale,
        "sequential_total_s": t_seq,
        "replan_overhead_s": replan_overhead,
        "incremental_overhead_s": inc_overhead,
        "replan_per_shift_s": replan_overhead / n,
        "incremental_per_shift_s": inc_overhead / n,
        "incremental_replans": inc_replans,
        "incremental_per_replan_s": inc_overhead / inc_replans,
        "cache": cache.stats.as_dict(),
        "speedup_vs_stale": t_stale / t_replan,
        "speedup_vs_sequential": t_seq / t_replan,
    })
    return rows


def main(rows=None) -> None:
    r = (run() if rows is None else rows)[0]
    print(f"task trajectory {r['trajectory']} × {ITERS_PER_PHASE} iters/phase")
    print(f"  re-plan each shift : {r['replan_total_s']:8.2f} s "
          f"(+{r['replan_per_shift_s']*1e3:.1f} ms planner/shift)")
    print(f"  incremental (cache): {r['incremental_total_s']:8.2f} s "
          f"(+{r['incremental_per_replan_s']*1e3:.1f} ms planner/replan "
          f"over {r['incremental_replans']} replans, "
          f"hit rate {r['cache']['hit_rate']:.0%}, "
          f"{r['cache']['warm_start_hits']} warm starts)")
    print(f"  stale initial plan : {r['stale_total_s']:8.2f} s "
          f"({r['speedup_vs_stale']:.2f}x slower)")
    print(f"  sequential baseline: {r['sequential_total_s']:8.2f} s "
          f"({r['speedup_vs_sequential']:.2f}x slower)")


if __name__ == "__main__":
    main()
