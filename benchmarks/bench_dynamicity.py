"""Appendix-D analogue — dynamicity: workload shift → re-plan payoff.

Tasks are added/completed over time (the paper §1: "the proportion of
different data modalities in MT workloads may shift over time").  We
compare four policies on a task-count trajectory:

  * ``replan``      — Spindle re-plans from scratch at every shift (the
                      paper's hook),
  * ``incremental`` — Spindle replans through the PlanCache: identical
                      workloads hit the cache outright, shifted workloads
                      reuse cached scaling curves and any unchanged
                      MetaLevels (repro.core.plancache),
  * ``stale``       — keep the plan built for the initial task set; removed
                      tasks leave holes, added tasks run sequentially after,
  * ``sequential``  — the workload-unaware baseline throughout.

Reported: total simulated time over the trajectory, per-policy planner wall
time per shift (< 0.2 s per shift, §Fig. 12), and the cache hit rate.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (
    ClusterSpec,
    PlanCache,
    plan,
    simulate_plan,
    simulate_sequential,
)
from repro.core.workloads import multitask_clip

TRAJECTORY = [4, 6, 6, 3, 5, 2]  # active task count per phase
ITERS_PER_PHASE = 25


def run() -> List[Dict]:
    cluster = ClusterSpec(n_devices=16, island_size=8, mem_bytes=96e9)
    rows = []

    # replan policy: full plan per phase
    t_replan, replan_overhead = 0.0, 0.0
    for k in TRAJECTORY:
        g = multitask_clip(k)
        t0 = time.perf_counter()
        p = plan(g, cluster)
        replan_overhead += time.perf_counter() - t0
        t_replan += simulate_plan(p, cluster).makespan * ITERS_PER_PHASE

    # incremental policy: plan through the PlanCache (exact hits + per-level
    # reuse + memoized scaling curves); correctness falls back to full replan
    cache = PlanCache()
    t_inc, inc_overhead = 0.0, 0.0
    for k in TRAJECTORY:
        g = multitask_clip(k)
        t0 = time.perf_counter()
        p = plan(g, cluster, cache=cache)
        inc_overhead += time.perf_counter() - t0
        t_inc += simulate_plan(p, cluster).makespan * ITERS_PER_PHASE

    # stale policy: the first phase's per-task time, applied to every phase
    # (removed tasks leave idle allocations; added tasks run sequentially)
    g0 = multitask_clip(TRAJECTORY[0])
    per_iter0 = simulate_plan(plan(g0, cluster), cluster).makespan
    t_stale = 0.0
    for k in TRAJECTORY:
        extra = 0.0
        if k > TRAJECTORY[0]:  # new tasks appended sequentially
            g_extra = multitask_clip(k)
            seq = simulate_sequential(g_extra, cluster)
            extra = seq.makespan * (k - TRAJECTORY[0]) / k
        t_stale += (per_iter0 + extra) * ITERS_PER_PHASE

    # sequential baseline
    t_seq = 0.0
    for k in TRAJECTORY:
        res = simulate_sequential(multitask_clip(k), cluster)
        t_seq += res.makespan * ITERS_PER_PHASE

    n = len(TRAJECTORY)
    rows.append({
        "bench": "dynamicity",
        "trajectory": TRAJECTORY,
        "replan_total_s": t_replan,
        "incremental_total_s": t_inc,
        "stale_total_s": t_stale,
        "sequential_total_s": t_seq,
        "replan_overhead_s": replan_overhead,
        "incremental_overhead_s": inc_overhead,
        "replan_per_shift_s": replan_overhead / n,
        "incremental_per_shift_s": inc_overhead / n,
        "cache": cache.stats.as_dict(),
        "speedup_vs_stale": t_stale / t_replan,
        "speedup_vs_sequential": t_seq / t_replan,
    })
    return rows


def main(rows=None) -> None:
    r = (run() if rows is None else rows)[0]
    print(f"task trajectory {r['trajectory']} × {ITERS_PER_PHASE} iters/phase")
    print(f"  re-plan each shift : {r['replan_total_s']:8.2f} s "
          f"(+{r['replan_per_shift_s']*1e3:.1f} ms planner/shift)")
    print(f"  incremental (cache): {r['incremental_total_s']:8.2f} s "
          f"(+{r['incremental_per_shift_s']*1e3:.1f} ms planner/shift, "
          f"hit rate {r['cache']['hit_rate']:.0%}, "
          f"{r['cache']['levels_reused']} levels reused)")
    print(f"  stale initial plan : {r['stale_total_s']:8.2f} s "
          f"({r['speedup_vs_stale']:.2f}x slower)")
    print(f"  sequential baseline: {r['sequential_total_s']:8.2f} s "
          f"({r['speedup_vs_sequential']:.2f}x slower)")


if __name__ == "__main__":
    main()
