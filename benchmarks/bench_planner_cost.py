"""Fig. 12 — execution-planner wall time (paper: < 3 s everywhere).

Each cell is measured three ways:

  * ``planner_s``     — cold full plan through the PlannerPipeline,
  * ``cached_s``      — the same workload again through a PlanCache
                        (exact signature hit),
  * ``incremental_s`` — a one-task workload shift replanned through the
                        cache (incremental path: memoized curves +
                        MetaLevel reuse where applicable).
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import ClusterSpec, PlanCache
from repro.core.plan import plan as mkplan
from repro.core.workloads import multitask_clip, ofasys, qwen_val


def run(smoke: bool = False) -> List[Dict]:
    """``smoke=True`` shrinks the grid (CI smoke job) — every row keeps the
    full metric schema so the BENCH_planner_cost.json key diff still holds."""
    grid = [
        ("multitask_clip", multitask_clip, 10),
        ("ofasys", ofasys, 7),
        ("qwen_val", qwen_val, 3),
    ]
    sizes = (16, 32, 64, 128)
    if smoke:
        grid, sizes = [("multitask_clip", multitask_clip, 6)], (16, 32)
    rows = []
    for name, maker, k in grid:
        for n in sizes:
            cluster = ClusterSpec(n_devices=n, island_size=8, mem_bytes=96e9)
            g = maker(k)
            t0 = time.perf_counter()
            p = mkplan(g, cluster)
            wall = time.perf_counter() - t0

            cache = PlanCache()
            mkplan(g, cluster, cache=cache)  # warm the cache
            t0 = time.perf_counter()
            mkplan(g, cluster, cache=cache)  # exact signature hit
            cached = time.perf_counter() - t0
            t0 = time.perf_counter()
            mkplan(maker(k - 1), cluster, cache=cache)  # one-task shift
            incremental = time.perf_counter() - t0

            rows.append(
                {
                    "bench": "planner_cost",
                    "workload": name,
                    "devices": n,
                    "planner_s": wall,
                    "cached_s": cached,
                    "incremental_s": incremental,
                    "cache_hit_rate": cache.stats.hit_rate,
                    "bracket_hits": cache.stats.bracket_hits,
                    "n_waves": len(p.waves()),
                    "n_steps": len(p.steps),
                }
            )
    return rows


def main(rows=None) -> None:
    rows = run() if rows is None else rows
    for r in rows:
        print(f"{r['workload']:18s} N={r['devices']:4d} "
              f"plan={r['planner_s']*1e3:8.1f} ms "
              f"hit={r['cached_s']*1e3:6.2f} ms "
              f"incr={r['incremental_s']*1e3:8.1f} ms "
              f"brk={r['bracket_hits']:4d} "
              f"waves={r['n_waves']:3d} steps={r['n_steps']:3d}")
    worst = max(r["planner_s"] for r in rows)
    print(f"worst planner time: {worst:.2f}s (paper: <3s)")


if __name__ == "__main__":
    main()
